"""End-to-end driver: a REAL JAX serving engine governed by the Autopoiesis
two-plane runtime.

The data plane serves batched requests through the continuous-batching engine
(a reduced qwen2 model on the host devices); the control plane concurrently
evolves the serving policy against the cluster-scale simulator and hot-swaps
superior policy code mid-serving.

    PYTHONPATH=src python examples/serve_autopoiesis.py
"""
import time

import jax

from repro.configs import get_config
from repro.core.evaluator import Evaluator
from repro.core.evolution import EvolutionConfig
from repro.core.plan import HARDWARE, QWEN25_FAMILY
from repro.core.policy import seed_policies
from repro.core.runtime import Autopoiesis
from repro.core.simulator import Simulator
from repro.models import lm
from repro.serving.engine import Engine, Request
from repro.traces import volatile_workload_trace


def main():
    # ---------------- real JAX engine (the physical data plane) -------------
    cfg = get_config("qwen2-1.5b").reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, n_slots=4, max_seq_len=96)
    applied_plans = []

    def backend_apply(plan, ctx):
        """Plan → engine reconfiguration (per-replica batch → slot count)."""
        applied_plans.append(plan)
        groups = plan.for_model(plan.groups[0].model) if plan.groups else []
        # here a production deployment would resize/migrate engine replicas;
        # we log the directive the plan issues
        if groups:
            g = groups[0]
            print(f"    [engine] plan applied: {g.gpu_type} tp={g.tp} "
                  f"batch={g.batch} × {g.count} replicas")

    # ---------------- two-plane Autopoiesis runtime --------------------------
    models = {m.name: m for m in QWEN25_FAMILY.values()}
    sim = Simulator(models, HARDWARE)
    evaluator = Evaluator(sim, models, HARDWARE)
    ap = Autopoiesis(evaluator, seed_policies()["greedy-reactive"],
                     EvolutionConfig(max_iterations=10, patience=10,
                                     evolution_timeout_s=45, seed=0),
                     window=8, evolve_every=3, backend_apply=backend_apply)

    trace = volatile_workload_trace()
    print("running the self-evolving loop over the runtime trace…")
    t0 = time.monotonic()
    served_tokens = 0
    for i, obs in enumerate(trace.observations):
        out = ap.data_plane.step(obs)
        # serve a burst of real requests through the JAX engine each step
        for r in range(3):
            engine.submit(Request(rid=i * 10 + r, prompt=[1 + r, 2, 3],
                                  max_new_tokens=6))
        done = engine.run_until_drained()
        served_tokens = sum(len(d.generated) for d in engine.finished)
        flag = " [HOT-SWAP]" if out["hot_swapped"] else ""
        print(f"  step {i}: rescheduled={out['rescheduled']} "
              f"interval={out['interval_total']:.1f}s{flag}")
        if i > 0 and i % 3 == 0:
            ap.control_plane.run_cycle(ap.data_plane.policy)

    acc = ap.data_plane.acc
    print(f"\nT_total={acc.T_total:.1f}s  N={acc.N}  "
          f"policy swaps={ap.data_plane.swap_count}  "
          f"evolution cycles={ap.control_plane.cycles}")
    print(f"real engine: {len(engine.finished)} requests, "
          f"{served_tokens} tokens in {time.monotonic() - t0:.1f}s wall")


if __name__ == "__main__":
    main()
