"""End-to-end driver: a REAL JAX engine pool governed by the Autopoiesis
two-plane runtime.

The data plane executes every serving plan on a plan-driven EnginePool
(reduced qwen2 replicas on the host devices): plan diffs rebuild only the
replica groups that changed, and the rebuild wall-clock is *measured*, not
simulated.  The control plane concurrently evolves the serving policy
against the cluster-scale simulator and hot-swaps superior policy code
mid-serving; each interval's measured TTFT/TPOT/tok/s/reconfig feed back
into the snapshot buffer the next evolution cycle trains on.

    PYTHONPATH=src python examples/serve_autopoiesis.py
"""
import time

from repro.core.evaluator import Evaluator
from repro.core.evolution import EvolutionConfig
from repro.core.plan import HARDWARE, QWEN25_FAMILY
from repro.core.policy import seed_policies
from repro.core.runtime import Autopoiesis
from repro.core.simulator import Simulator
from repro.serving.backend import make_jax_backend
from repro.serving.shadow import ShadowReplayEval
from repro.traces import volatile_workload_trace


def main():
    # ---------------- real JAX engine pool (the physical data plane) --------
    backend = make_jax_backend("qwen2-1.5b", max_seq_len=96, slots_cap=4,
                               max_replicas_per_group=1, requests_per_model=1)

    # ---------------- two-plane Autopoiesis runtime --------------------------
    # the sjf-request seed is a v2 PolicyProgram: its request-domain hooks
    # (admit/prioritize) are pushed to the engine pool and govern slot
    # admission order instead of FIFO
    models = {m.name: m for m in QWEN25_FAMILY.values()}
    sim = Simulator(models, HARDWARE)
    evaluator = Evaluator(sim, models, HARDWARE)
    # evaluation ladder rung 2: deterministic shadow replay, so request- and
    # reconfig-domain candidates are fitness-ranked before reaching serving,
    # and every publish is canaried against the incumbent's trailing window
    shadow = ShadowReplayEval(sim, models, HARDWARE, candidate_timeout_s=20.0)
    ap = Autopoiesis(evaluator, seed_policies()["sjf-request"],
                     EvolutionConfig(max_iterations=10, patience=10,
                                     evolution_timeout_s=45, seed=0,
                                     shadow_top_k=3),
                     window=8, evolve_every=3, backend=backend,
                     shadow=shadow, canary_intervals=2)
    # blend measured reconfiguration wall-clock AND request-level tail
    # latency/backlog into the fitness accounting
    ap.data_plane.acc.measured_blend = 0.25
    ap.data_plane.acc.measured_scale = 50.0   # toy-engine seconds → cluster
    ap.data_plane.acc.request_blend = 0.1
    rp = backend.pool.request_policy
    print(f"request policy installed on the pool: "
          f"{rp.name if rp else None} (domains={ap.data_plane.policy.domains})")

    trace = volatile_workload_trace()
    print("running the self-evolving loop over the runtime trace…")
    t0 = time.monotonic()
    swapped_since_cycle = False
    for i, obs in enumerate(trace.observations):
        out = ap.data_plane.step(obs)
        rep, met = out["reconfig_report"], out["metrics"]
        flag = " [HOT-SWAP]" if out["hot_swapped"] else ""
        swapped_since_cycle = swapped_since_cycle or out["hot_swapped"]
        line = (f"  step {i}: rescheduled={out['rescheduled']} "
                f"interval={out['interval_total']:.1f}s{flag}")
        if out["canary"] is not None:
            c = out["canary"]
            line += (f"\n    [canary] {c['candidate']}: {c['status']}"
                     + (f" — {c['reason']}" if c.get("reason") else ""))
        if rep is not None and rep.changed:
            who = " evolved-policy" if swapped_since_cycle else " seed-policy"
            line += (f"\n    [pool]{who} reconfig: built={len(rep.built)} "
                     f"reused={len(rep.reused)} removed={len(rep.removed)} "
                     f"drained={rep.drained_requests} "
                     f"migrated={rep.migrated_requests} "
                     f"recomputed={rep.recomputed_requests} "
                     f"measured={rep.wall_s * 1e3:.1f}ms "
                     f"(sim estimate {rep.simulated_s:.1f}s)")
        if met is not None:
            line += (f"\n    [serve] {met.requests} req {met.tokens} tok "
                     f"ttft={met.ttft_s * 1e3:.0f}ms "
                     f"(p50 {met.ttft_p50_s * 1e3:.0f} / "
                     f"p95 {met.ttft_p95_s * 1e3:.0f}) "
                     f"tpot={met.tpot_s * 1e3:.0f}ms "
                     f"{met.tokens_per_s:.1f} tok/s")
        print(line)
        if i > 0 and i % 3 == 0:
            ap.control_plane.run_cycle(ap.data_plane.policy)

    acc = ap.data_plane.acc
    measured_recs = [r for r in acc.records if r.measured_reconfig_s > 0]
    print(f"\nT_total={acc.T_total:.1f}s  N={acc.N}  "
          f"policy swaps={ap.data_plane.swap_count}  "
          f"evolution cycles={ap.control_plane.cycles} "
          f"(skipped={ap.control_plane.skipped_cycles}, "
          f"shadow finalists ranked per cycle)")
    print(f"guarded rollout: commits={ap.data_plane.commits} "
          f"rollbacks={ap.data_plane.rollbacks} "
          f"{ap.data_plane.rollback_reasons}")
    print(f"pool: {backend.pool.reconfig_count} reconfigurations, "
          f"{len(measured_recs)} interval records carry measured reconfig "
          f"wall-clock (Σ={acc.sum_measured_reconfig * 1e3:.1f}ms), "
          f"{len(backend.pool.finished)} requests served on real engines "
          f"({backend.pool.total_dispatches} jitted dispatches) "
          f"in {time.monotonic() - t0:.1f}s wall")


if __name__ == "__main__":
    main()
