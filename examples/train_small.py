"""Train a ~100M-param model for a few hundred steps with the fault-tolerant
training loop (checkpoint/resume, NaN guard, grad accumulation).

    PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.training import data as dl
from repro.training import optim
from repro.training.trainer import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # ~100M params: 8 layers × d_model 768 (qwen2-family shape)
    cfg = ModelConfig(name="qwen2-100m", family="dense", n_layers=8,
                      d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
                      d_ff=2048, vocab_size=32000, qkv_bias=True,
                      tie_embeddings=True)
    print(f"model: {cfg.param_count() / 1e6:.0f}M params")
    dcfg = dl.DataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                         global_batch=8)
    tcfg = TrainConfig(steps=args.steps, microbatches=4, ckpt_every=100,
                       ckpt_dir=args.ckpt_dir,
                       opt=optim.AdamWConfig(lr=1e-3, warmup_steps=30))
    report = train(cfg, tcfg, dcfg,
                   on_step=lambda s, l: print(f"  step {s:4d} loss {l:.4f}")
                   if s % 25 == 0 else None)
    print(f"done: loss {report.losses[0]:.3f} → {report.losses[-1]:.3f} "
          f"({report.steps_done} steps, resumed={report.resumed_from}, "
          f"nan-skips={report.skipped_nan})")


if __name__ == "__main__":
    main()
