"""Quickstart: evolve a serving policy for a runtime trace in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.evaluator import Evaluator
from repro.core.evolution import Evolution, EvolutionConfig
from repro.core.plan import HARDWARE, QWEN25_FAMILY
from repro.core.policy import seed_policies
from repro.core.simulator import Simulator
from repro.traces import volatile_workload_trace


def main():
    # 1. the world: models, hardware, and the Appendix-B roofline simulator
    models = {m.name: m for m in QWEN25_FAMILY.values()}
    sim = Simulator(models, HARDWARE)
    evaluator = Evaluator(sim, models, HARDWARE)

    # 2. a snapshotted runtime trace (volatile workload, heterogeneous cluster)
    trace = volatile_workload_trace()

    # 3. score the human-engineered seed policies (greedy / ILP / hybrid…)
    print("— seed policies —")
    for name, pol in seed_policies().items():
        r = evaluator.evaluate(pol, trace)
        print(f"  {name:24s} T_total={r.fitness:9.1f}s  N={r.N} "
              f"reconfig={r.sum_reconfig:6.1f}s")

    # 4. evolve: MAP-Elites + islands + trade-off-aware mutation
    evo = Evolution(evaluator, EvolutionConfig(
        max_iterations=40, evolution_timeout_s=120, seed=0))
    state = evo.run(trace)
    best = state.best
    print("\n— evolved policy —")
    print(f"  T_total={best.fitness:.1f}s  N={best.result.N} "
          f"reconfig={best.result.sum_reconfig:.1f}s "
          f"({state.iterations_run} iterations)")
    print(f"  genome: {best.policy.genome}")
    print("\n— evolved policy source (first 25 lines) —")
    print("\n".join(best.policy.source.splitlines()[:25]))


if __name__ == "__main__":
    main()
