"""Elastic-cluster scenario (§8.2): spot preemptions force reconfiguration;
the evolved policy discovers partial-migration strategies.

    PYTHONPATH=src python examples/spot_elastic.py
"""
from repro.core.evaluator import Evaluator
from repro.core.evolution import Evolution, EvolutionConfig
from repro.core.plan import HARDWARE, QWEN25_FAMILY
from repro.core.policy import render_policy
from repro.core.simulator import Simulator
from repro.traces.workload import elastic_cluster_traces


def main():
    models = {m.name: m for m in QWEN25_FAMILY.values()}
    sim = Simulator(models, HARDWARE)
    ev = Evaluator(sim, models, HARDWARE)

    full = render_policy({"scheduler": "bnb", "time_budget": 5.0,
                          "batch_scheme": "sweet", "allow_split": True,
                          "trigger_kind": "always"}, name="full-migration")
    minimal = render_policy({"scheduler": "greedy", "trigger_kind": "threshold",
                             "shift_threshold": 9.9,
                             "migration_keep_threshold": 4.0,
                             "reconfig_penalty": 8.0}, name="minimal-migration")

    for name, trace in elastic_cluster_traces().items():
        print(f"=== {name} (cluster sizes: "
              f"{[o.cluster.total for o in trace.observations]}) ===")
        for pol in (full, minimal):
            r = ev.evaluate(pol, trace)
            print(f"  {pol.name:18s} T={r.fitness:7.1f}s "
                  f"reconfig={r.sum_reconfig:6.1f}s stale={r.sum_stale:5.1f}s")
        best = Evolution(ev, EvolutionConfig(max_iterations=25,
                                             evolution_timeout_s=90,
                                             seed=0)).run(trace).best
        r = best.result
        print(f"  {'evolved':18s} T={r.fitness:7.1f}s "
              f"reconfig={r.sum_reconfig:6.1f}s stale={r.sum_stale:5.1f}s")
        print(f"  evolved genome: "
              f"{ {k: v for k, v in best.policy.genome.items() if k in ('reconfig_penalty', 'migration_keep_threshold', 'trigger_kind', 'scheduler')} }")


if __name__ == "__main__":
    main()
